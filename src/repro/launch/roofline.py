"""Roofline-term derivation from compiled dry-run artifacts.

Terms (per device; the compiled SPMD program is the per-chip program, so
dividing global quantities by chip count is equivalent):

  compute    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw              (819 GB/s)
  collective = collective_bytes / link_bw      (~50 GB/s/link ICI)

``cost_analysis`` supplies FLOPs and bytes. Collective bytes are parsed
from the optimized HLO: for each all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction we count the bytes the op moves
through ICI per device:
  all-gather         -> result bytes minus the local shard (received data)
  reduce-scatter     -> operand bytes minus the local shard (sent data)
  all-reduce         -> 2x operand bytes (ring reduce + broadcast phases)
  all-to-all         -> operand bytes (everything leaves the chip once)
  collective-permute -> operand bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

# TPU v5e per-chip hardware constants (from the assignment brief)
PEAK_FLOPS_BF16 = 197e12
HBM_GBPS = 819e9
ICI_LINK_GBPS = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "tuple": 0, "token": 0, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _parse_shapes(text: str) -> List[int]:
    return [_shape_bytes(m.group(1), m.group(2))
            for m in _SHAPE_RE.finditer(text)]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    count_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        m = re.search(
            r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVE_KINDS)
            + r")(?:-start|-done)?\(", ls
        )
        if m is None:
            continue
        kind = m.group(1)
        if "-done(" in ls:
            continue  # counted at -start
        lhs, _, rhs = ls.partition("=")
        result_bytes = sum(_parse_shapes(rhs.split("(", 1)[0]))
        operand_bytes = sum(_parse_shapes(rhs.split("(", 1)[1]))
        # group size for shard arithmetic
        gs = 0
        gm = re.search(r"replica_groups=\{\{([\d,]+)\}", ls)
        if gm:
            gs = len(gm.group(1).split(","))
        gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", ls)
        if gm2:
            gs = int(gm2.group(2))
        frac = (gs - 1) / gs if gs > 1 else 1.0
        if kind == "all-gather":
            moved = int(result_bytes * frac)
        elif kind == "reduce-scatter":
            moved = int(operand_bytes * frac)
        elif kind == "all-reduce":
            moved = int(2 * operand_bytes * frac)
        else:  # all-to-all, collective-permute
            moved = operand_bytes
        bytes_by_kind[kind] += moved
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    flops_ratio: float = 0.0        # MODEL_FLOPS / HLO_FLOPs (global)
    per_device_bytes: Optional[int] = None
    collective_counts: Optional[Dict[str, int]] = None

    def as_row(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_ratio": self.flops_ratio,
        }


def roofline_terms(
    flops: float,
    hbm_bytes: float,
    coll: CollectiveStats,
    model_flops: float = 0.0,
    n_chips: int = 1,
) -> Roofline:
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm_bytes / HBM_GBPS
    collective_s = coll.total_bytes / ICI_LINK_GBPS
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    ratio = (
        model_flops / (flops * n_chips) if flops else 0.0
    )
    return Roofline(
        flops=flops,
        hbm_bytes=hbm_bytes,
        collective_bytes=coll.total_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops,
        flops_ratio=ratio,
        collective_counts=dict(coll.count_by_kind),
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D for training (N = active params, D = tokens);
    2*N*D for inference; decode processes one token per sequence."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


def active_param_count(cfg) -> int:
    """Active (per-token) parameters: MoE counts only top_k experts."""
    total = cfg.param_count()
    if cfg.uses_moe:
        per_layer_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe = sum(
            1 for _, ch in cfg.layer_plan() if ch == "moe"
        ) * cfg.n_periods
        inactive = n_moe * (cfg.n_experts - cfg.top_k) * per_layer_expert
        total -= inactive
    return total
