import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract memory / cost / collective stats.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the dry-run (and ONLY the
dry-run) needs 512 placeholder CPU devices for the 2x16x16 mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--all] [--out results.json]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, INPUT_SHAPES, get_config
from ..distributed.sharding import (
    batch_shardings,
    cache_shardings,
    data_pspec,
    params_shardings,
    replicated,
)
from ..launch.mesh import make_production_mesh
from ..launch.roofline import (
    CollectiveStats,
    collective_stats,
    model_flops_estimate,
    roofline_terms,
)
from ..launch.specs import abstract_state, input_specs, make_step
from ..models.init import abstract_params
from jax.sharding import NamedSharding, PartitionSpec as P


def _cost_get(cost, key: str) -> float:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get(key, 0.0))


def _body_cost(cfg, shape, mesh, kind, specs, params) -> Optional[Dict]:
    """Compile ONE standalone super-block (the scan body) under the same
    mesh/shardings and return its (flops, bytes, collective) cost.

    XLA's cost model counts a while-loop body once, so the scanned module
    understates per-step cost by ~n_periods; the dry-run reports
    corrected = module + (n_periods - 1) x body. Validated against fully
    unrolled lowering (see EXPERIMENTS.md §Dry-run).
    """
    import jax.numpy as jnp

    from ..launch.specs import effective_window, sds
    from ..models.transformer import super_block

    W = effective_window(cfg, INPUT_SHAPES[shape.name])
    strip = lambda tree: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), tree
    )
    pp = strip(params["blocks"])
    pp_sh = params_shardings(pp, mesh)
    B = shape.global_batch
    S = 1 if kind == "decode" else shape.seq_len
    x = sds((B, S, cfg.d_model), cfg.dtype)
    x_sh = batch_shardings(x, mesh)
    frontend = specs.get("frontend") if isinstance(specs, dict) else None
    if kind == "train" and "batch" in specs:
        frontend = specs["batch"].get("frontend")
    f_args = [frontend] if frontend is not None else []
    f_sh = [batch_shardings(frontend, mesh)] if frontend is not None else []

    if kind == "train":
        def body(pp, x, *fa):
            fr = fa[0] if fa else None

            def f(pp_, x_):
                out, _, aux = super_block(
                    pp_, x_, cfg, mode="train", frontend=fr,
                    caches=None, cache_len=None, window=0,
                )
                return jnp.sum(out.astype(jnp.float32)) + aux

            # value_and_grad keeps the primal forward alive (grad alone
            # lets XLA DCE it, undercounting remat fwd+fwd+bwd ~ 4x fwd)
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat_policy == "dots" else None
            )
            return jax.value_and_grad(
                jax.checkpoint(f, policy=policy), argnums=(0, 1)
            )(pp, x)

        jitted = jax.jit(body, in_shardings=(pp_sh, x_sh, *f_sh))
        lowered = jitted.lower(pp, x, *f_args)
    else:
        if "caches" in specs:
            caches_p = strip(specs["caches"])
        else:  # prefill creates its caches internally; rebuild abstractly
            from ..models.transformer import init_caches

            caches_p = strip(
                jax.eval_shape(
                    lambda: init_caches(cfg, B, shape.seq_len, W)
                )
            )
        c_sh = cache_shardings(caches_p, mesh)
        if kind == "prefill":
            def body(pp, x, caches, *fa):
                return super_block(
                    pp, x, cfg, mode="prefill",
                    frontend=fa[0] if fa else None,
                    caches=caches, cache_len=None, window=W,
                )
            jitted = jax.jit(body, in_shardings=(pp_sh, x_sh, c_sh, *f_sh))
            lowered = jitted.lower(pp, x, caches_p, *f_args)
        else:
            clen = sds((), jnp.int32)
            def body(pp, x, caches, cache_len, *fa):
                return super_block(
                    pp, x, cfg, mode="decode",
                    frontend=fa[0] if fa else None,
                    caches=caches, cache_len=cache_len, window=W,
                )
            jitted = jax.jit(
                body,
                in_shardings=(pp_sh, x_sh, c_sh, replicated(mesh), *f_sh),
            )
            lowered = jitted.lower(pp, x, caches_p, clen, *f_args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return {
        "flops": _cost_get(cost, "flops"),
        "bytes": _cost_get(cost, "bytes accessed"),
        "coll": coll,
    }


def dryrun_one(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    verbose: bool = True,
    scan_layers: bool = True,
    correct_scan: bool = True,
) -> Dict[str, Any]:
    """Lower+compile one combo. ``scan_layers=True`` keeps compile time
    bounded (layers as a lax.scan); ``correct_scan`` then compiles one
    standalone super-block and reports module + (n_periods-1) x body so
    the roofline terms match the fully-unrolled ground truth (validated:
    tinyllama train_4k unrolled vs corrected agree within a few %)."""
    import dataclasses

    from ..distributed.sharding import OPT as _OPT0

    cfg = get_config(arch, shape=shape_name)
    repl = dict(
        scan_layers=scan_layers,
        remat_policy="dots" if _OPT0["remat_dots"] else "full",
        moe_ep=_OPT0["moe_ep"],
    )
    if _OPT0.get("ssm_chunk"):
        repl["ssm_chunk"] = int(_OPT0["ssm_chunk"])
    cfg = dataclasses.replace(cfg, **repl)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    step_fn, kind = make_step(cfg, shape)
    specs = input_specs(cfg, shape)
    t0 = time.monotonic()

    with mesh:
        params = abstract_params(cfg)
        p_sh = params_shardings(params, mesh)
        rep = replicated(mesh)
        if kind == "train":
            from ..training.optimizer import init_adamw

            from ..distributed.sharding import OPT as _OPTz, zero1_shardings

            opt = jax.eval_shape(lambda: init_adamw(params))
            shard_fn = (
                zero1_shardings if _OPTz["zero1"] else params_shardings
            )
            o_sh = shard_fn({"mu": opt.mu, "nu": opt.nu}, mesh)
            opt_sh = type(opt)(step=rep, mu=o_sh["mu"], nu=o_sh["nu"])
            b_sh = batch_shardings(specs["batch"], mesh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_sh, opt_sh, b_sh),
                out_shardings=(p_sh, opt_sh, None),
            )
            lowered = jitted.lower(params, opt, specs["batch"])
        elif kind == "prefill":
            in_sh = [p_sh] + [
                batch_shardings(specs[k], mesh)
                for k in ("tokens", "frontend", "inputs_embeds")
                if k in specs
            ]
            args = [params] + [
                specs[k]
                for k in ("tokens", "frontend", "inputs_embeds")
                if k in specs
            ]
            jitted = jax.jit(
                step_fn, in_shardings=tuple(in_sh), out_shardings=None
            )
            lowered = jitted.lower(*args)
        else:  # decode
            c_sh = cache_shardings(specs["caches"], mesh)
            in_sh = [p_sh, batch_shardings(specs["token"], mesh), c_sh, rep]
            args = [params, specs["token"], specs["caches"],
                    specs["cache_len"]]
            if "frontend" in specs:
                in_sh.append(batch_shardings(specs["frontend"], mesh))
                args.append(specs["frontend"])
            from ..distributed.sharding import OPT as _OPT

            jitted = jax.jit(
                step_fn,
                in_shardings=tuple(in_sh),
                out_shardings=(
                    NamedSharding(mesh, data_pspec(
                        (shape.global_batch, cfg.vocab), mesh)),
                    c_sh,
                ),
                donate_argnums=(2,) if _OPT["donate_caches"] else (),
            )
            lowered = jitted.lower(*args)

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    flops = _cost_get(cost, "flops")
    hbm_bytes = _cost_get(cost, "bytes accessed")
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    if scan_layers and correct_scan and cfg.n_periods > 1:
        with mesh:
            body = _body_cost(cfg, shape, mesh, kind, specs, params)
        k = cfg.n_periods - 1
        flops += k * body["flops"]
        hbm_bytes += k * body["bytes"]
        bc: CollectiveStats = body["coll"]
        for kk in coll.bytes_by_kind:
            coll.bytes_by_kind[kk] += k * bc.bytes_by_kind[kk]
            coll.count_by_kind[kk] += k * bc.count_by_kind[kk]
    mf = model_flops_estimate(cfg, shape)
    rf = roofline_terms(flops, hbm_bytes, coll, model_flops=mf,
                        n_chips=n_chips)

    mem_fields = {}
    for f in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
    ):
        v = getattr(mem, f, None)
        if v is not None:
            mem_fields[f] = int(v)

    from ..distributed.sharding import OPT

    result = {
        "arch": arch,
        "shape": shape_name,
        "kind": kind,
        "opt": ",".join(sorted(k for k, v in OPT.items() if v)),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops,
        "hbm_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll.total_bytes,
        "collective_counts": coll.count_by_kind,
        "compute_s": rf.compute_s,
        "memory_s": rf.memory_s,
        "collective_s": rf.collective_s,
        "dominant": rf.dominant,
        "model_flops": mf,
        "flops_ratio": rf.flops_ratio,
        "memory_analysis": mem_fields,
    }
    if verbose:
        print(
            f"[{result['mesh']}] {arch} x {shape_name} ({kind}): "
            f"compile {t_compile:.1f}s  "
            f"flops/dev {flops:.3g}  hbm/dev {hbm_bytes:.3g}B  "
            f"coll/dev {coll.total_bytes:.3g}B  dominant={rf.dominant}  "
            f"useful-flops-ratio {rf.flops_ratio:.2f}"
        )
        print(f"  memory_analysis: {mem_fields}")
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all 10 archs x 4 shapes")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--opt", default="",
                    help="comma-separated perf options (kv_seq_shard, "
                         "zero1, donate_caches, remat_dots, moe_ep) — "
                         "see §Perf")
    ap.add_argument("--ssm-chunk", type=int, default=0,
                    help="override cfg.ssm_chunk (§Perf hillclimb C)")
    args = ap.parse_args()

    from ..distributed.sharding import OPT

    for o in filter(None, args.opt.split(",")):
        assert o in OPT, f"unknown opt {o}"
        OPT[o] = True
    if args.ssm_chunk:
        OPT["ssm_chunk"] = args.ssm_chunk

    combos = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = (
        list(INPUT_SHAPES) if (args.all or args.shape is None)
        else [args.shape]
    )
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    results = []
    failures = 0
    for a, s, mp in combos:
        try:
            r = dryrun_one(a, s, multi_pod=mp)
        except Exception as e:
            failures += 1
            r = {
                "arch": a, "shape": s,
                "mesh": "2x16x16" if mp else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}",
            }
            print(f"FAIL {a} x {s} ({r['mesh']}): {r['error']}")
            traceback.print_exc()
        results.append(r)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(r) + "\n")
    print(f"\n{len(results) - failures}/{len(results)} combos compiled OK")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
