"""ShapeDtypeStruct input specs for every (architecture x input-shape)
combination — weak-type-correct, shardable, zero allocation — plus the
step-callable constructors the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import INPUT_SHAPES, InputShape, ModelConfig, get_config
from ..models import decode_step, init_caches, loss_fn, prefill
from ..models.init import abstract_params
from ..training.optimizer import AdamWConfig, adamw_update, init_adamw
from ..training.train_loop import TrainConfig, make_train_step


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def effective_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding window applies to dense-family archs at 500k decode."""
    return cfg.attn_window


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Device-side KV length: ring window if windowed, else full seq."""
    w = effective_window(cfg, shape)
    return min(w, shape.seq_len) if w else shape.seq_len


def input_specs(
    cfg: ModelConfig, shape: InputShape
) -> Dict[str, Any]:
    """Abstract inputs for the step function of this shape's kind."""
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": sds((B, S)),
            "labels": sds((B, S)),
        }
        if cfg.cross_attn_every:
            batch["frontend"] = sds((B, cfg.n_frontend_tokens, d), cfg.dtype)
        if cfg.family == "audio":
            # frame embeddings from the (stubbed) codec frontend
            batch["inputs_embeds"] = sds((B, S, d), cfg.dtype)
        return {"batch": batch}
    if shape.kind == "prefill":
        out: Dict[str, Any] = {"tokens": sds((B, S))}
        if cfg.cross_attn_every:
            out["frontend"] = sds((B, cfg.n_frontend_tokens, d), cfg.dtype)
        if cfg.family == "audio":
            out["inputs_embeds"] = sds((B, S, d), cfg.dtype)
        return out
    # decode: ONE new token against a seq_len KV cache
    W = effective_window(cfg, shape)
    caches = jax.eval_shape(
        lambda: init_caches(cfg, B, S, W)
    )
    out = {
        "token": sds((B,)),
        "caches": caches,
        "cache_len": sds((), jnp.int32),
    }
    if cfg.cross_attn_every:
        out["frontend"] = sds((B, cfg.n_frontend_tokens, d), cfg.dtype)
    return out


# ---------------------------------------------------------------------------
# Step callables
# ---------------------------------------------------------------------------
def make_step(cfg: ModelConfig, shape: InputShape) -> Tuple[Callable, str]:
    """Returns (fn, kind). Signatures:
    train:   fn(params, opt_state, batch) -> (params, opt_state, metrics)
    prefill: fn(params, **specs) -> (logits, caches, cache_len)
    decode:  fn(params, token, caches, cache_len[, frontend]) ->
             (logits, caches)
    """
    W = effective_window(cfg, shape)
    if shape.kind == "train":
        tc = TrainConfig(remat=True, opt=AdamWConfig())
        return make_train_step(cfg, tc), "train"
    if shape.kind == "prefill":
        def prefill_step(params, tokens, frontend=None, inputs_embeds=None):
            return prefill(
                params, tokens, cfg, max_len=shape.seq_len, window=W,
                frontend=frontend, inputs_embeds=inputs_embeds,
            )
        return prefill_step, "prefill"

    def serve_step(params, token, caches, cache_len, frontend=None):
        return decode_step(
            params, token, caches, cache_len, cfg, window=W,
            frontend=frontend,
        )
    return serve_step, "decode"


def abstract_state(cfg: ModelConfig, with_opt: bool = False):
    params = abstract_params(cfg)
    if not with_opt:
        return params
    opt = jax.eval_shape(lambda: init_adamw(params))
    return params, opt
