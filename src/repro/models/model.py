"""LM wrapper: embeddings -> scanned blocks -> head; train / prefill /
decode entry points used by the launcher, serving engine and dry-run."""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import BATCH, MODEL, cross_entropy_loss, embed, rms_norm, shard, unembed
from .transformer import init_caches, run_blocks


def forward(
    params: Dict,
    tokens: Optional[jax.Array],
    cfg,
    *,
    mode: str = "train",
    inputs_embeds: Optional[jax.Array] = None,
    frontend: Optional[jax.Array] = None,
    caches: Optional[List] = None,
    cache_len: Optional[jax.Array] = None,
    window: int = 0,
    remat: bool = False,
):
    """Returns (logits, new_caches, aux_loss).

    ``inputs_embeds`` replaces token embedding for audio frontends
    (precomputed frame embeddings — the stubbed modality carve-out);
    ``frontend`` feeds cross-attention layers (VLM patch embeddings).
    """
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cfg.dtype)
    else:
        x = embed(params, tokens)
    x = shard(x, BATCH, None, None)
    x, new_caches, aux = run_blocks(
        params, x, cfg, mode=mode, frontend=frontend, caches=caches,
        cache_len=cache_len, window=window, remat=remat,
    )
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(params, x)
    logits = shard(logits, BATCH, None, MODEL)
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
def loss_fn(
    params: Dict,
    batch: Dict,
    cfg,
    *,
    aux_weight: float = 0.01,
    remat: bool = True,
):
    logits, _, aux = forward(
        params, batch["tokens"], cfg, mode="train",
        frontend=batch.get("frontend"),
        inputs_embeds=batch.get("inputs_embeds"),
        remat=remat,
    )
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


def prefill(
    params: Dict,
    tokens: jax.Array,
    cfg,
    *,
    max_len: int,
    window: int = 0,
    frontend: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
):
    """Process a prompt, returning (last-position logits, caches, length)."""
    B, S = (
        tokens.shape if tokens is not None else inputs_embeds.shape[:2]
    )
    caches = init_caches(cfg, B, max_len, window)
    logits, caches, _ = forward(
        params, tokens, cfg, mode="prefill", caches=caches,
        cache_len=jnp.zeros((), jnp.int32), window=window,
        frontend=frontend, inputs_embeds=inputs_embeds,
    )
    return logits[:, -1], caches, jnp.array(S, jnp.int32)


def decode_step(
    params: Dict,
    token: jax.Array,            # (B,) or (B,1) token ids
    caches: List,
    cache_len: jax.Array,        # scalar int32
    cfg,
    *,
    window: int = 0,
    frontend: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
):
    """One decode step: returns (logits (B, vocab), new caches)."""
    if token is not None and token.ndim == 1:
        token = token[:, None]
    logits, new_caches, _ = forward(
        params, token, cfg, mode="decode", caches=caches,
        cache_len=cache_len, window=window, frontend=frontend,
        inputs_embeds=inputs_embeds,
    )
    return logits[:, 0], new_caches
