"""Expert-parallel Mixture-of-Experts channel mixer.

Scatter-based dispatch (no GShard dense dispatch tensors): tokens are
scattered into per-expert capacity buffers with positions derived from a
cumulative count, experts run as a batched einsum over the expert axis
(sharded over the ``model`` mesh axis = expert parallelism), and outputs
are gathered back with router-probability weighting. Top-k routing with
capacity dropping and the standard load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import BATCH, MODEL, shard


def router(params: Dict, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Returns (top-k probs, top-k expert indices); probs renormalized."""
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.clip(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    return top_p.astype(x.dtype), top_i, probs


def load_balance_loss(probs: jax.Array, top_i: jax.Array, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    sel = jax.nn.one_hot(top_i, n_experts, dtype=jnp.float32)  # (B,S,k,E)
    frac_tokens = jnp.mean(jnp.mean(sel, axis=2), axis=(0, 1))  # (E,), sums to 1
    mean_prob = jnp.mean(probs, axis=(0, 1))                    # (E,)
    return n_experts * jnp.sum(frac_tokens * mean_prob)


def moe_ffn(
    params: Dict, x: jax.Array, cfg, *, return_aux: bool = False
):
    """x: (B, S, d). Each batch row is a dispatch group with its own
    capacity C = ceil(S * top_k / E * capacity_factor)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    top_p, top_i, probs = router(params, x, cfg)

    C = max(1, int((S * K / E) * cfg.capacity_factor + 0.9999))
    C = min(C, S * K)

    # Position of each (token, k) assignment within its expert's buffer:
    # running count of prior assignments to the same expert in this group.
    sel = jax.nn.one_hot(top_i, E, dtype=jnp.int32)          # (B, S, K, E)
    flat = sel.reshape(B, S * K, E)
    pos_flat = jnp.cumsum(flat, axis=1) - flat               # prior count
    pos = jnp.sum(pos_flat.reshape(B, S, K, E) * sel, axis=-1)  # (B, S, K)
    keep = (pos < C).astype(x.dtype)                         # capacity drop
    pos_c = jnp.minimum(pos, C - 1)

    # Scatter tokens into (B, E, C, d) expert buffers.
    bidx = jnp.arange(B)[:, None, None]                      # (B,1,1)
    contrib = x[:, :, None, :] * keep[..., None]             # (B, S, K, d)
    buffers = jnp.zeros((B, E, C, d), x.dtype).at[
        bidx, top_i, pos_c
    ].add(contrib)
    buffers = shard(buffers, BATCH, MODEL, None, None)

    # Batched expert FFN (SwiGLU), expert axis sharded over `model`.
    h_gate = jnp.einsum("becd,edf->becf", buffers, params["w_gate"])
    h_up = jnp.einsum("becd,edf->becf", buffers, params["w_up"])
    h = jax.nn.silu(h_gate) * h_up
    h = shard(h, BATCH, MODEL, None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    out_buf = shard(out_buf, BATCH, MODEL, None, None)

    # Gather back to token order with router weighting.
    gathered = out_buf[bidx, top_i, pos_c]                   # (B, S, K, d)
    y = jnp.sum(gathered * (top_p * keep)[..., None], axis=2)
    y = shard(y, BATCH, None, None)
    if return_aux:
        return y, load_balance_loss(probs, top_i, E)
    return y
