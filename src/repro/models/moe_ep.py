"""Expert-parallel MoE with explicit all-to-all (shard_map).

The pjit scatter-dispatch formulation (moe.py) is correct but XLA's SPMD
partitioner lowers the (B, E, C, d) buffer construction as full-buffer
all-reduces — ~730 GB/device/step on olmoe train_4k (§Perf hillclimb B).
The communication-optimal schedule is the classic expert-parallel
all-to-all: tokens are sequence-sharded over the ``model`` axis, each
shard routes locally, exchanges per-expert capacity buffers with a single
all_to_all, runs its local experts, and all_to_alls back. Predicted
volume: B*S*k*cf*d*2 bytes/device/layer (~167 MB for olmoe) instead of
full-buffer all-reduces — a ~40x reduction.
"""
from __future__ import annotations

import inspect
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import _current_mesh, mesh_axis_sizes
from .moe import moe_ffn

# jax >= 0.6 exposes shard_map at top level; 0.4.x has it under
# jax.experimental. The replication-check knob was renamed check_rep ->
# check_vma independently of that move, so pick it from the signature.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - exercised on jax 0.4.x only
    from jax.experimental.shard_map import shard_map as _shard_map
_SHARD_MAP_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep"
)


def _batch_axes(sizes) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in sizes)


def moe_ffn_ep(params: Dict, x: jax.Array, cfg, *, return_aux: bool = False):
    """Drop-in for moe_ffn; falls back when no model axis / E not
    divisible. x: (B, S, d)."""
    sizes = mesh_axis_sizes()
    m = sizes.get("model", 1)
    E, K = cfg.n_experts, cfg.top_k
    if m == 1 or E % m != 0:
        return moe_ffn(params, x, cfg, return_aux=return_aux)

    mesh = _current_mesh()
    B, S, d = x.shape
    S_pad = math.ceil(S / m) * m
    if S_pad != S:
        x = jnp.pad(x, ((0, 0), (0, S_pad - S), (0, 0)))
    ba = _batch_axes(sizes)
    x_spec = P(ba if ba else None, "model", None)
    E_loc = E // m
    cf = cfg.capacity_factor

    def local(router, wg, wu, wd, xl):
        Bl, Sl, _ = xl.shape
        N = Bl * Sl
        xt = xl.reshape(N, d)
        logits = jnp.einsum(
            "nd,de->ne", xt.astype(jnp.float32), router.astype(jnp.float32)
        )
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, K)
        top_p = (top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
                 ).astype(xt.dtype)
        C = max(1, int(N * K / E * cf + 0.9999))
        C = min(C, N * K)

        sel = jax.nn.one_hot(top_i, E, dtype=jnp.int32)        # (N,K,E)
        flat = sel.reshape(N * K, E)
        pos_flat = jnp.cumsum(flat, axis=0) - flat
        pos = jnp.sum(pos_flat.reshape(N, K, E) * sel, axis=-1)  # (N,K)
        keep = (pos < C).astype(xt.dtype)
        pos_c = jnp.minimum(pos, C - 1)

        buf = jnp.zeros((E, C, d), xt.dtype).at[top_i, pos_c].add(
            xt[:, None, :] * keep[..., None]
        )
        # exchange: shard-major expert order — shard j owns experts
        # [j*E_loc, (j+1)*E_loc)
        sent = jax.lax.all_to_all(
            buf.reshape(m, E_loc, C, d), "model",
            split_axis=0, concat_axis=0, tiled=False,
        )                                                       # (m,E_loc,C,d)
        hg = jnp.einsum("mecd,edf->mecf", sent, wg)
        hu = jnp.einsum("mecd,edf->mecf", sent, wu)
        h = jax.nn.silu(hg) * hu
        out = jnp.einsum("mecf,efd->mecd", h, wd)
        back = jax.lax.all_to_all(
            out, "model", split_axis=0, concat_axis=0, tiled=False
        ).reshape(E, C, d)
        y = back[top_i, pos_c]                                  # (N,K,d)
        y = jnp.sum(y * (top_p * keep)[..., None], axis=1)
        y = y.reshape(Bl, Sl, d)

        # load-balance loss, averaged over every mesh axis
        fr = jnp.mean(
            jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=(0, 1)
        )
        mp = jnp.mean(probs, axis=0)
        axes_all = ("model",) + ba
        fr = jax.lax.pmean(fr, axes_all)
        mp = jax.lax.pmean(mp, axes_all)
        aux = E * jnp.sum(fr * mp)
        return y, aux

    y, aux = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), x_spec),
        out_specs=(x_spec, P()),
        **{_SHARD_MAP_CHECK_KW: False},
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)
    if S_pad != S:
        y = y[:, :S]
    if return_aux:
        return y, aux
    return y
