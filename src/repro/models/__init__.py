"""Model zoo: composable JAX transformer stack (dense GQA, MoE, Mamba2
SSD, hybrid, VLM cross-attention, audio decoder)."""
from .attention import (
    cross_attention,
    decode_self_attention,
    gqa,
    init_kv_cache,
    self_attention,
)
from .init import abstract_params, init_params, param_bytes
from .layers import (
    BATCH,
    MODEL,
    cross_entropy_loss,
    embed,
    mlp_forward,
    pspec,
    rms_norm,
    rope,
    shard,
    unembed,
)
from .model import decode_step, forward, loss_fn, prefill
from .moe import moe_ffn
from .ssm import mamba_block, mamba_block_decode, ssd_chunked, ssd_decode_step
from .transformer import init_caches, run_blocks
