"""Attention: GQA self-attention (full / sliding-window), decode with
linear or ring-buffer KV caches, and cross-attention to frontend
embeddings (VLM patches / audio conditioning frames).

All math runs grouped (B, S, G, H/G, D) so GQA never materializes repeated
KV heads; softmax accumulates in fp32.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import BATCH, MODEL, mesh_axis_sizes, rope, shard

NEG_INF = -1e30


def shard_kv_cache(x: jax.Array) -> jax.Array:
    """KV cache (B, T, G, D): heads over `model` when divisible; otherwise
    the sequence axis goes there when the kv_seq_shard perf option is on
    (must agree with distributed.sharding.cache_pspec or XLA inserts
    full-cache reshards every layer)."""
    sizes = mesh_axis_sizes()
    m = sizes.get("model", 1)
    if m > 1 and x.shape[2] % m != 0:
        from ..distributed.sharding import OPT

        if OPT["kv_seq_shard"]:
            return shard(x, BATCH, MODEL, None, None)
    return shard(x, BATCH, None, MODEL, None)


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------
def qkv_proj(params: Dict, x: jax.Array, cfg) -> Tuple[jax.Array, ...]:
    B, S, _ = x.shape
    H, G, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].reshape(cfg.d_model, H, D))
    k = jnp.einsum("bsd,dgk->bsgk", x, params["wk"].reshape(cfg.d_model, G, D))
    v = jnp.einsum("bsd,dgk->bsgk", x, params["wv"].reshape(cfg.d_model, G, D))
    if cfg.qkv_bias:
        q = q + params["bq"].reshape(H, D)
        k = k + params["bk"].reshape(G, D)
        v = v + params["bv"].reshape(G, D)
    q = shard(q, BATCH, None, MODEL, None)
    k = shard(k, BATCH, None, MODEL, None)
    v = shard(v, BATCH, None, MODEL, None)
    return q, k, v


def out_proj(params: Dict, o: jax.Array, cfg) -> jax.Array:
    B, S = o.shape[:2]
    return jnp.einsum(
        "bshk,hkd->bsd", o, params["wo"].reshape(cfg.n_heads, cfg.hd, cfg.d_model)
    )


# ---------------------------------------------------------------------------
# Core grouped attention
# ---------------------------------------------------------------------------
def gqa(
    q: jax.Array,                 # (B, Sq, H, D)
    k: jax.Array,                 # (B, T, G, D)
    v: jax.Array,                 # (B, T, G, D)
    mask: Optional[jax.Array],    # broadcastable to (B, 1, 1, Sq, T)
) -> jax.Array:
    B, Sq, H, D = q.shape
    G = k.shape[2]
    R = H // G
    qg = q.reshape(B, Sq, G, R, D)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg, k).astype(jnp.float32)
    scores = scores * (D ** -0.5)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bgrst,btgd->bsgrd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, D)


def causal_mask(
    q_pos: jax.Array, kv_pos: jax.Array, window: int = 0
) -> jax.Array:
    """(Sq, T) -> broadcast (1, 1, 1, Sq, T). Window 0 = unlimited."""
    m = kv_pos[None, :] <= q_pos[:, None]
    if window:
        m &= kv_pos[None, :] > q_pos[:, None] - window
    return m[None, None, None]


# ---------------------------------------------------------------------------
# Train / prefill self-attention
# ---------------------------------------------------------------------------
def self_attention(
    params: Dict,
    x: jax.Array,
    cfg,
    *,
    window: int = 0,
    return_cache: bool = False,
):
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q, k, v = qkv_proj(params, x, cfg)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    if getattr(cfg, "attn_impl", "xla") == "pallas":
        from ..kernels.flash_attention.ops import flash_attention_op

        bq = max(16, min(128, S))
        while S % bq:
            bq //= 2
        o = flash_attention_op(
            q, k, v, causal=True, window=window,
            block_q=bq, block_k=bq, interpret=True,
        )
    else:
        mask = causal_mask(pos, pos, window)
        o = gqa(q, k, v, mask)
    y = out_proj(params, o, cfg)
    if return_cache:
        return y, {"k": k, "v": v}
    return y


# ---------------------------------------------------------------------------
# Decode self-attention with KV cache
# ---------------------------------------------------------------------------
def init_kv_cache(cfg, batch: int, max_len: int, window: int = 0) -> Dict:
    """Linear cache (window=0) or ring buffer of size ``window``."""
    W = window if window else max_len
    shape = (batch, W, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
    }


def _ring_kv_positions(cache_len: jax.Array, W: int) -> jax.Array:
    """Absolute position stored in each ring slot after writing position
    ``cache_len`` at slot ``cache_len % W``. Slots not yet written map to
    negative positions (masked out)."""
    s = jnp.arange(W)
    return cache_len - ((cache_len - s) % W)


def decode_self_attention(
    params: Dict,
    x: jax.Array,              # (B, 1, d) — the new token's hidden state
    cache: Dict,               # {"k","v"}: (B, W, G, D)
    cache_len: jax.Array,      # scalar int32: tokens already in the cache
    cfg,
    *,
    window: int = 0,
) -> Tuple[jax.Array, Dict]:
    B = x.shape[0]
    W = cache["k"].shape[1]
    q, k_new, v_new = qkv_proj(params, x, cfg)
    q = rope(q, cache_len[None] if cache_len.ndim == 0 else cache_len,
             cfg.rope_theta)
    k_new = rope(k_new, jnp.full((1,), 0, jnp.int32) + cache_len,
                 cfg.rope_theta)
    slot = cache_len % W if window else cache_len
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1
    )
    k_cache = shard_kv_cache(k_cache)
    v_cache = shard_kv_cache(v_cache)
    if window:
        kv_pos = _ring_kv_positions(cache_len, W)
        valid = kv_pos >= 0
    else:
        kv_pos = jnp.arange(W)
        valid = kv_pos <= cache_len
    mask = valid[None, None, None, None, :]
    o = gqa(q, k_cache, v_cache, mask)
    y = out_proj(params, o, cfg)
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Cross-attention (modality frontend consumption; no causal mask, no RoPE)
# ---------------------------------------------------------------------------
def cross_attention(
    params: Dict,
    x: jax.Array,           # (B, S, d) decoder states
    frontend: jax.Array,    # (B, F, d) precomputed patch/frame embeddings
    cfg,
) -> jax.Array:
    H, G, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum(
        "bsd,dhk->bshk", x, params["wq"].reshape(cfg.d_model, H, D)
    )
    k = jnp.einsum(
        "bfd,dgk->bfgk", frontend, params["wk_cross"].reshape(cfg.d_model, G, D)
    )
    v = jnp.einsum(
        "bfd,dgk->bfgk", frontend, params["wv_cross"].reshape(cfg.d_model, G, D)
    )
    q = shard(q, BATCH, None, MODEL, None)
    o = gqa(q, k, v, mask=None)
    return out_proj(params, o, cfg)
