"""Layer-stack composition: scan over super-blocks of ``cfg.period``
layers (MaxText-style stacked params — keeps HLO size and compile time
independent of depth), supporting heterogeneous interleaves (hybrid
attn:ssm, MoE cadence, VLM cross-attention cadence).
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    cross_attention,
    decode_self_attention,
    init_kv_cache,
    self_attention,
)
from .layers import BATCH, MODEL, rms_norm, shard
from .moe import moe_ffn
from .ssm import init_ssm_state, mamba_block, mamba_block_decode


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def init_caches(cfg, batch: int, max_len: int, window: int = 0) -> List:
    """Per-period-position cache pytrees with a leading n_periods axis."""
    caches: List = []
    for mixer, _ in cfg.layer_plan():
        if mixer == "attn":
            one = init_kv_cache(cfg, batch, max_len, window)
        elif mixer == "ssm":
            one = init_ssm_state(cfg, batch)
        else:  # cross_attn has no mutable state
            one = {}
        caches.append(
            jax.tree.map(
                lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), one
            )
        )
    return caches


# ---------------------------------------------------------------------------
# One super-block (cfg.period layers)
# ---------------------------------------------------------------------------
def super_block(
    params_period: List[Dict],
    x: jax.Array,
    cfg,
    *,
    mode: str,                       # train | prefill | decode
    frontend: Optional[jax.Array],
    caches: Optional[List],
    cache_len: Optional[jax.Array],
    window: int,
):
    new_caches: List = []
    aux = jnp.zeros((), jnp.float32)
    for j, (mixer, channel) in enumerate(cfg.layer_plan()):
        p = params_period[j]
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        cache_j = caches[j] if caches is not None else None
        if mixer == "attn":
            if mode == "decode":
                y, new_cache = decode_self_attention(
                    p, h, cache_j, cache_len, cfg, window=window
                )
            elif mode == "prefill":
                y, kv = self_attention(
                    p, h, cfg, window=window, return_cache=True
                )

                # Write prefix KV into the cache. For a ring buffer
                # (window mode) only the last W positions survive, placed
                # at slot = position % W so decode continues seamlessly.
                def _write(c, fresh):
                    fresh = fresh.astype(c.dtype)
                    S, W = fresh.shape[1], c.shape[1]
                    if window and S >= W:
                        return jnp.roll(fresh[:, S - W:], S % W, axis=1)
                    return jax.lax.dynamic_update_slice_in_dim(
                        c, fresh, 0, axis=1
                    )

                new_cache = jax.tree.map(_write, cache_j, kv)
            else:
                y = self_attention(p, h, cfg, window=window)
                new_cache = cache_j
        elif mixer == "ssm":
            if mode == "decode":
                y, new_cache = mamba_block_decode(p, h, cache_j, cfg)
            elif mode == "prefill":
                y, state = mamba_block(p, h, cfg, return_state=True)
                new_cache = jax.tree.map(
                    lambda c, s: s.astype(c.dtype), cache_j, state
                )
            else:
                y = mamba_block(p, h, cfg)
                new_cache = cache_j
        else:  # cross_attn
            y = cross_attention(p, h, frontend, cfg)
            new_cache = cache_j if cache_j is not None else {}
        x = x + y
        x = shard(x, BATCH, None, None)
        if channel != "none":
            h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
            if channel == "moe":
                if cfg.moe_ep:
                    from .moe_ep import moe_ffn_ep

                    y2, aux_j = moe_ffn_ep(p, h2, cfg, return_aux=True)
                else:
                    y2, aux_j = moe_ffn(p, h2, cfg, return_aux=True)
                aux = aux + aux_j
            else:
                from .layers import mlp_forward

                y2 = mlp_forward(p, h2, cfg.mlp)
            x = x + y2
            x = shard(x, BATCH, None, None)
        new_caches.append(new_cache)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Full stack: scan over periods
# ---------------------------------------------------------------------------
def run_blocks(
    params: Dict,
    x: jax.Array,
    cfg,
    *,
    mode: str = "train",
    frontend: Optional[jax.Array] = None,
    caches: Optional[List] = None,
    cache_len: Optional[jax.Array] = None,
    window: int = 0,
    remat: bool = False,
):
    """Returns (hidden, new_caches, aux_loss)."""
    blocks = params["blocks"]           # leaves: (n_periods, ...)
    have_caches = caches is not None

    def body(carry_x, per):
        params_period, caches_period = per
        out, new_caches, aux = super_block(
            params_period, carry_x, cfg,
            mode=mode, frontend=frontend,
            caches=caches_period if have_caches else None,
            cache_len=cache_len, window=window,
        )
        return out, (new_caches if have_caches else 0, aux)

    if remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots" else None
        )
        body = jax.checkpoint(body, policy=policy)

    xs = (blocks, caches if have_caches else jnp.zeros((cfg.n_periods,)))
    if cfg.scan_layers:
        x, (new_caches, auxs) = jax.lax.scan(body, x, xs)
        return x, (new_caches if have_caches else None), jnp.sum(auxs)

    # Unrolled: accurate XLA cost analysis (scan bodies are counted once
    # by the cost model); used by the dry-run.
    news, aux_total = [], jnp.zeros((), jnp.float32)
    for i in range(cfg.n_periods):
        per = jax.tree.map(lambda a: a[i], xs)
        x, (nc, aux) = body(x, per)
        news.append(nc)
        aux_total = aux_total + aux
    if have_caches:
        new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *news)
    else:
        new_caches = None
    return x, new_caches, aux_total
