"""Parameter initialization (stacked-per-period layout for layer scanning).

``init_params`` returns the real pytree (used by smoke tests, examples,
training); ``abstract_params`` returns ShapeDtypeStructs via ``eval_shape``
so the multi-pod dry-run never allocates memory.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp


def _dense(rng, shape, dtype, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(dtype)


def init_layer(rng, cfg, mixer: str, channel: str) -> Dict:
    d, dt = cfg.d_model, cfg.dtype
    H, G, D = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(rng, 24)
    p: Dict = {"ln1": jnp.ones((d,), dt)}
    if mixer in ("attn", "cross_attn"):
        p["wq"] = _dense(ks[0], (d, H * D), dt)
        p["wo"] = _dense(ks[3], (H * D, d), dt)
        if mixer == "attn":
            p["wk"] = _dense(ks[1], (d, G * D), dt)
            p["wv"] = _dense(ks[2], (d, G * D), dt)
            if cfg.qkv_bias:
                p["bq"] = jnp.zeros((H * D,), dt)
                p["bk"] = jnp.zeros((G * D,), dt)
                p["bv"] = jnp.zeros((G * D,), dt)
        else:
            p["wk_cross"] = _dense(ks[1], (d, G * D), dt)
            p["wv_cross"] = _dense(ks[2], (d, G * D), dt)
    elif mixer == "ssm":
        di = cfg.ssm_d_inner
        gn = cfg.ssm_groups * cfg.ssm_state
        h = cfg.ssm_heads
        w = cfg.conv_width
        p["w_z"] = _dense(ks[4], (d, di), dt)
        p["w_x"] = _dense(ks[5], (d, di), dt)
        p["w_B"] = _dense(ks[6], (d, gn), dt)
        p["w_C"] = _dense(ks[7], (d, gn), dt)
        p["w_dt"] = _dense(ks[8], (d, h), dt)
        p["conv_x_w"] = _dense(ks[9], (w, di), dt, scale=w ** -0.5)
        p["conv_x_b"] = jnp.zeros((di,), dt)
        p["conv_B_w"] = _dense(ks[10], (w, gn), dt, scale=w ** -0.5)
        p["conv_B_b"] = jnp.zeros((gn,), dt)
        p["conv_C_w"] = _dense(ks[11], (w, gn), dt, scale=w ** -0.5)
        p["conv_C_b"] = jnp.zeros((gn,), dt)
        p["dt_bias"] = jnp.full((h,), 0.5, dt)
        p["A_log"] = jnp.zeros((h,), jnp.float32)
        p["D"] = jnp.ones((h,), dt)
        p["norm"] = jnp.ones((di,), dt)
        p["w_out"] = _dense(ks[12], (di, d), dt)
    if channel == "mlp":
        p["ln2"] = jnp.ones((d,), dt)
        p["w_gate"] = _dense(ks[13], (d, cfg.d_ff), dt)
        p["w_up"] = _dense(ks[14], (d, cfg.d_ff), dt)
        p["w_down"] = _dense(ks[15], (cfg.d_ff, d), dt)
    elif channel == "moe":
        E = cfg.n_experts
        p["ln2"] = jnp.ones((d,), dt)
        p["router"] = _dense(ks[16], (d, E), jnp.float32)
        p["w_gate"] = _dense(ks[17], (E, d, cfg.d_ff), dt)
        p["w_up"] = _dense(ks[18], (E, d, cfg.d_ff), dt)
        p["w_down"] = _dense(ks[19], (E, cfg.d_ff, d), dt)
    return p


def init_period(rng, cfg) -> List[Dict]:
    plan = cfg.layer_plan()
    keys = jax.random.split(rng, len(plan))
    return [
        init_layer(k, cfg, mixer, channel)
        for k, (mixer, channel) in zip(keys, plan)
    ]


def init_params(rng, cfg) -> Dict:
    k_embed, k_head, k_blocks = jax.random.split(rng, 3)
    params: Dict = {
        "embedding": _dense(k_embed, (cfg.vocab, cfg.d_model), cfg.dtype,
                            scale=0.02),
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = _dense(k_head, (cfg.d_model, cfg.vocab), cfg.dtype)
    # vmap over periods stacks every leaf with a leading n_periods axis
    params["blocks"] = jax.vmap(lambda k: init_period(k, cfg))(
        jax.random.split(k_blocks, cfg.n_periods)
    )
    return params


def abstract_params(cfg):
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)
    )


def param_bytes(cfg) -> int:
    tree = abstract_params(cfg)
    return sum(
        int(jnp.prod(jnp.array(l.shape))) * l.dtype.itemsize
        for l in jax.tree.leaves(tree)
    )
