"""Common neural-net building blocks (pure JAX, pjit-compatible).

Sharding is expressed through ``shard(x, ...)`` constraints that no-op when
no mesh is active (CPU smoke tests) and bind to whatever subset of the
production axes ("pod", "data", "model") the active mesh defines.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# Logical batch axes: sharded over pod+data when present.
BATCH: Tuple[str, ...] = ("pod", "data")
MODEL = "model"


def _current_mesh():
    """The mesh governing this trace: the sharding-in-types abstract mesh
    if set, else the legacy ``with mesh:`` context (which is how pjit
    launchers and the dry-run provide it)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return am
    except Exception:  # pragma: no cover
        pass
    try:
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            from jax.interpreters import pxla

            pm = pxla.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:  # pragma: no cover
        pass
    return None


def active_mesh_axes() -> frozenset:
    m = _current_mesh()
    return frozenset(m.axis_names) if m is not None else frozenset()


def mesh_axis_sizes() -> dict:
    m = _current_mesh()
    return dict(m.shape) if m is not None else {}


def pspec(*spec: Axis, dims: Optional[Sequence[int]] = None) -> P:
    """Build a PartitionSpec keeping only axes the active mesh defines and
    (when ``dims`` is given) only where the dimension is divisible by the
    mesh-axis size — e.g. 56 attention heads cannot shard 16 ways, and 8 KV
    heads on a 16-way model axis stay replicated (Megatron GQA rule)."""
    sizes = mesh_axis_sizes()

    def filt(e: Axis, dim: Optional[int]):
        if e is None:
            return None
        if isinstance(e, str):
            e = (e,)
        t = tuple(a for a in e if a in sizes)
        if not t:
            return None
        total = 1
        for a in t:
            total *= sizes[a]
        if dim is not None and dim % total != 0:
            return None
        return t if len(t) > 1 else t[0]

    if dims is None:
        dims = [None] * len(spec)
    return P(*[filt(e, d) for e, d in zip(spec, dims)])


def shard(x: jax.Array, *spec: Axis) -> jax.Array:
    """Apply a sharding constraint if a mesh is active; no-op otherwise.
    Drops axes that don't divide the corresponding dimension."""
    if not mesh_axis_sizes():
        return x
    return jax.lax.with_sharding_constraint(
        x, pspec(*spec, dims=x.shape)
    )


# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope(
    x: jax.Array, positions: jax.Array, theta: float = 10_000.0
) -> jax.Array:
    """Rotary position embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def mlp_forward(params: dict, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    """Gated MLP: SwiGLU (llama-family) or GeGLU (gemma)."""
    h_gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
    h_up = jnp.einsum("...d,df->...f", x, params["w_up"])
    act = jax.nn.gelu(h_gate) if kind == "geglu" else jax.nn.silu(h_gate)
    h = shard(act * h_up, BATCH, None, MODEL)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params: dict, x: jax.Array) -> jax.Array:
    w = params.get("head", params["embedding"])
    if w.shape[0] != x.shape[-1]:
        return jnp.einsum("...d,vd->...v", x, w)
    return jnp.einsum("...d,dv->...v", x, w)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
