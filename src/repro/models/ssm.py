"""Mamba2 / SSD (state-space duality) token mixer [arXiv:2405.21060].

Chunked SSD: within a chunk the recurrence is computed in its quadratic
"attention-like" dual form (MXU-friendly); across chunks a linear scan
carries the (heads, headdim, dstate) state. Decode is the O(1) recurrent
update. Projections are kept separate (w_z/w_x/w_B/w_C/w_dt rather than one
fused in_proj) so each output lands on a cleanly shardable axis — a TPU
adaptation noted in DESIGN.md.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import BATCH, MODEL, rms_norm, shard


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def segsum(a: jax.Array) -> jax.Array:
    """(..., l) log-decays -> (..., l, l) cumulative segment sums;
    entry [i, j] = a[j+1] + ... + a[i] for i >= j, -inf above diagonal."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    xbar: jax.Array,    # (b, l, h, p)  — inputs pre-multiplied by dt
    a: jax.Array,       # (b, l, h)     — per-step log decay (negative)
    B: jax.Array,       # (b, l, g, n)
    C: jax.Array,       # (b, l, g, n)
    chunk: int,
    initial_state: Optional[jax.Array] = None,   # (b, h, p, n)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (b, l, h, p), final_state: (b, h, p, n)).

    g (B/C groups) must divide h; groups broadcast over h//g heads.
    """
    b, l, h, p = xbar.shape
    g, n = B.shape[2], B.shape[3]
    l_orig = l
    if l % chunk:
        # Pad to a chunk multiple: a=0 (decay 1) and xbar=0 leave the
        # carried state untouched; padded outputs are sliced off below.
        pad = chunk - l % chunk
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // chunk
    r = h // g

    # reshape to chunks
    xc = xbar.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)      # (b,h,nc,cl)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    a_cs = jnp.cumsum(ac, axis=-1)                              # (b,h,nc,cl)

    # 1) intra-chunk (dual quadratic form)
    L = jnp.exp(segsum(ac))                                     # (b,h,nc,cl,cl)
    # heads grouped over B/C groups: h = g * r
    Lr = L.reshape(b, g, r, nc, chunk, chunk)
    xr = xc.reshape(b, nc, chunk, g, r, p)
    scores = jnp.einsum("bcign,bcsgn->bgcis", Cc, Bc)           # (b,g,nc,cl,cl)
    y_diag = jnp.einsum(
        "bgcis,bgrcis,bcsgrp->bcigrp", scores, Lr, xr
    )

    # 2) per-chunk end states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)               # (b,h,nc,cl)
    dsr = decay_states.reshape(b, g, r, nc, chunk)
    states = jnp.einsum("bcsgn,bgrcs,bcsgrp->bcgrpn", Bc, dsr, xr)
    states = states.reshape(b, nc, h, p, n)

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(a_cs[..., -1]).transpose(0, 2, 1)     # (b,nc,h)
    s0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((b, h, p, n), xbar.dtype)
    )

    def step(s, inp):
        dec, st = inp                                            # (b,h), (b,h,p,n)
        s_new = s * dec[..., None, None] + st
        return s_new, s                                          # emit state *before* chunk

    final_state, prev_states = jax.lax.scan(
        step,
        s0.astype(jnp.float32),
        (
            chunk_decay.transpose(1, 0, 2).astype(jnp.float32),
            states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
        ),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (b,nc,h,p,n)

    # 4) contribution of carried state to each position
    state_decay = jnp.exp(a_cs)                                  # (b,h,nc,cl)
    sdr = state_decay.reshape(b, g, r, nc, chunk)
    psr = prev_states.astype(xbar.dtype).reshape(b, nc, g, r, p, n)
    y_off = jnp.einsum("bcign,bcgrpn,bgrci->bcigrp", Cc, psr, sdr)

    y = (y_diag + y_off).reshape(b, l, h, p)[:, :l_orig]
    return y, final_state.astype(xbar.dtype)


def ssd_decode_step(
    state: jax.Array,   # (b, h, p, n)
    x: jax.Array,       # (b, h, p) — new token input
    dt: jax.Array,      # (b, h)
    a: jax.Array,       # (b, h) log decay
    B: jax.Array,       # (b, g, n)
    C: jax.Array,       # (b, g, n)
) -> Tuple[jax.Array, jax.Array]:
    b, h, p, n = state.shape
    g = B.shape[1]
    r = h // g
    Bh = jnp.repeat(B, r, axis=1)                                # (b,h,n)
    Ch = jnp.repeat(C, r, axis=1)
    xbar = x * dt[..., None]
    state = state * jnp.exp(a)[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xbar, Bh
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y, state


# ---------------------------------------------------------------------------
# Depthwise causal conv (width cfg.conv_width)
# ---------------------------------------------------------------------------
def causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """x: (b, l, ch), w: (width, ch) depthwise. Causal (left) padding."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.transpose(0, 2, 1)[:, :, None, :],       # (b, ch, 1, l+w-1)
        w.T[:, None, None, :],                       # (ch, 1, 1, w)
        window_strides=(1, 1),
        padding="VALID",
        feature_group_count=x.shape[-1],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[:, :, 0, :].transpose(0, 2, 1) + bias


def conv_decode_step(
    conv_state: jax.Array,   # (b, width-1, ch)
    x_new: jax.Array,        # (b, 1, ch)
    w: jax.Array,
    bias: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    window = jnp.concatenate([conv_state, x_new], axis=1)        # (b, width, ch)
    y = jnp.einsum("bwc,wc->bc", window, w)[:, None, :] + bias
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------
def _project(params: Dict, x: jax.Array, cfg):
    z = jnp.einsum("bld,de->ble", x, params["w_z"])
    xin = jnp.einsum("bld,de->ble", x, params["w_x"])
    Bp = jnp.einsum("bld,de->ble", x, params["w_B"])
    Cp = jnp.einsum("bld,de->ble", x, params["w_C"])
    dt = jnp.einsum("bld,dh->blh", x, params["w_dt"])
    return z, xin, Bp, Cp, dt


def mamba_block(
    params: Dict,
    x: jax.Array,                 # (b, l, d)
    cfg,
    *,
    return_state: bool = False,
    initial_state: Optional[Dict] = None,
):
    """``initial_state``/returned state follow the ``init_ssm_state``
    schema ({ssm, conv_x, conv_B, conv_C}) so prefill -> decode
    continuation is exact (SSM state + conv tails)."""
    b, l, d = x.shape
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    tail = cfg.conv_width - 1
    z, xin_raw, Bp_raw, Cp_raw, dt = _project(params, x, cfg)
    xin = causal_conv(xin_raw, params["conv_x_w"], params["conv_x_b"])
    Bp = causal_conv(Bp_raw, params["conv_B_w"], params["conv_B_b"])
    Cp = causal_conv(Cp_raw, params["conv_C_w"], params["conv_C_b"])
    xin = jax.nn.silu(xin)
    Bp = jax.nn.silu(Bp)
    Cp = jax.nn.silu(Cp)
    xin = shard(xin, BATCH, None, MODEL)
    dt = jax.nn.softplus(dt + params["dt_bias"])                 # (b,l,h)
    a = -jnp.exp(params["A_log"]) * dt                           # (b,l,h)
    xh = xin.reshape(b, l, h, p)
    xbar = xh * dt[..., None]
    s0 = initial_state["ssm"] if initial_state is not None else None
    y, final_ssm = ssd_chunked(
        xbar, a, Bp.reshape(b, l, g, n), Cp.reshape(b, l, g, n),
        cfg.ssm_chunk, s0,
    )
    y = y + params["D"][:, None] * xh                            # skip
    y = y.reshape(b, l, h * p).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["w_out"]).astype(x.dtype)
    if return_state:
        state = {
            "ssm": final_ssm,
            # conv tails: last (width-1) raw projections, for exact decode
            "conv_x": xin_raw[:, l - tail:, :],
            "conv_B": Bp_raw[:, l - tail:, :],
            "conv_C": Cp_raw[:, l - tail:, :],
        }
        return out, state
    return out


def init_ssm_state(cfg, batch: int) -> Dict:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    ch = cfg.ssm_d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    w = cfg.conv_width - 1
    return {
        "ssm": jnp.zeros((batch, h, p, n), cfg.dtype),
        "conv_x": jnp.zeros((batch, w, ch), cfg.dtype),
        "conv_B": jnp.zeros((batch, w, gn), cfg.dtype),
        "conv_C": jnp.zeros((batch, w, gn), cfg.dtype),
    }


def mamba_block_decode(
    params: Dict,
    x: jax.Array,        # (b, 1, d)
    state: Dict,         # from init_ssm_state
    cfg,
) -> Tuple[jax.Array, Dict]:
    b = x.shape[0]
    h, p, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xin, Bp, Cp, dt = _project(params, x, cfg)
    xin, conv_x = conv_decode_step(
        state["conv_x"], xin, params["conv_x_w"], params["conv_x_b"]
    )
    Bp, conv_B = conv_decode_step(
        state["conv_B"], Bp, params["conv_B_w"], params["conv_B_b"]
    )
    Cp, conv_C = conv_decode_step(
        state["conv_C"], Cp, params["conv_C_w"], params["conv_C_b"]
    )
    xin = jax.nn.silu(xin)[:, 0]                                 # (b, di)
    Bp = jax.nn.silu(Bp)[:, 0]
    Cp = jax.nn.silu(Cp)[:, 0]
    dt = jax.nn.softplus(dt[:, 0] + params["dt_bias"])           # (b,h)
    a = -jnp.exp(params["A_log"]) * dt
    y, ssm = ssd_decode_step(
        state["ssm"], xin.reshape(b, h, p), dt, a,
        Bp.reshape(b, g, n), Cp.reshape(b, g, n),
    )
    y = y + params["D"][:, None] * xin.reshape(b, h, p)
    y = y.reshape(b, 1, h * p).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["w_out"]).astype(x.dtype)
    return out, {
        "ssm": ssm.astype(state["ssm"].dtype),
        "conv_x": conv_x.astype(state["conv_x"].dtype),
        "conv_B": conv_B.astype(state["conv_B"].dtype),
        "conv_C": conv_C.astype(state["conv_C"].dtype),
    }
