"""MMA on the pod fabric: multipath weight distribution / KV fetch as
jit-able collective programs.

The paper's relay insight — land chunks on every host's local links in
parallel, then forward over the accelerator interconnect — is expressed in
JAX as a resharding program: weights enter host-chunked (every host's PCIe
path carries 1/N of the payload into its local chips' HBM) and an
all-gather/collective-permute schedule over ICI assembles the serving
layout. ``wakeup_step`` lowers exactly this; the dry-run counts its
collective bytes, and the sim engine provides the PCIe-stage timing.

This is the TPU-native generalization recorded in DESIGN.md §2.1: on an
8-GPU server the relay set is 7 peers; on a pod it is every chip's host
link, and the "NVLink hop" becomes the compiled ICI schedule.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import batch_axes, params_shardings


def staging_shardings(abstract_params: Any, mesh: Mesh):
    """Ingest layout: every parameter chunked over ALL mesh axes on its
    largest dimension — each chip's host link lands an equal slice
    (the multipath ingest), regardless of the serving layout."""
    axes = tuple(mesh.axis_names)

    def spec(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return NamedSharding(mesh, P())
        total = mesh.devices.size
        # chunk the largest divisible dim over all axes
        order = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % total == 0:
                s = [None] * len(shape)
                s[i] = axes
                return NamedSharding(mesh, P(*s))
        # fall back: replicate (tiny tensors below chip count)
        return NamedSharding(mesh, P())

    return jax.tree.map(spec, abstract_params)


def make_wakeup_step(cfg, mesh: Mesh):
    """jit'd resharding program: host-chunked staging -> serving layout.

    Returns (fn, staging_shardings, serving_shardings). Lower with
    abstract params to count the ICI collective schedule; run with real
    arrays to perform an actual multipath wake-up.
    """
    from ..models.init import abstract_params

    aparams = abstract_params(cfg)
    stage_sh = staging_shardings(aparams, mesh)
    serve_sh = params_shardings(aparams, mesh)

    def wakeup(params):
        # identity math; the resharding IS the program
        return params

    fn = jax.jit(wakeup, in_shardings=(stage_sh,), out_shardings=serve_sh)
    return fn, stage_sh, serve_sh


def make_kv_fetch_step(cfg, mesh: Mesh, batch: int, seq: int, window: int = 0):
    """Host-pool KV pages enter chunked over all chips; the program
    reshards them into the decode cache layout."""
    from ..models.transformer import init_caches
    from .sharding import cache_shardings

    caches = jax.eval_shape(lambda: init_caches(cfg, batch, seq, window))
    stage_sh = staging_shardings(caches, mesh)
    serve_sh = cache_shardings(caches, mesh)

    def fetch(caches):
        return caches

    fn = jax.jit(fetch, in_shardings=(stage_sh,), out_shardings=serve_sh)
    return fn, caches, stage_sh, serve_sh
