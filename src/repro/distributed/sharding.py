"""Sharding rules: parameter / batch / cache PartitionSpecs per
architecture for the production meshes.

Megatron-style 2D(+pod) layout:
  * weights tensor-parallel over ``model`` (attention head projections,
    MLP hidden dim, MoE expert dim, Mamba inner dim), replicated over
    ``data``/``pod``;
  * batch sharded over (``pod``, ``data``);
  * decode KV caches shard batch over (pod, data) when divisible, else the
    sequence axis over ``data`` (long_500k batch=1);
  * optimizer moments follow their parameter (ZeRO-1 over ``data`` is a
    perf-pass option, see EXPERIMENTS.md §Perf).

Every rule is divisibility-guarded: a dim that doesn't divide the mesh
axis stays replicated (e.g. yi-34b's 56 heads on a 16-way model axis shard
on the flattened head*head_dim projection instead; mamba2's 50280 vocab
embedding stays replicated).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= _axis_size(mesh, n)
        return s
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _guard(mesh: Mesh, shape: Tuple[int, ...], spec: Tuple) -> P:
    """Drop spec entries whose mesh-axis size doesn't divide the dim."""
    out = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            out.append(None)
            continue
        if dim % _axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


BATCH_AXES = ("pod", "data")

# Perf-pass options (EXPERIMENTS.md §Perf). Baseline = all False; the
# dry-run CLI toggles them per hillclimb run so paper-faithful and
# optimized lowering are recorded separately.
OPT: Dict[str, bool] = {
    # decode KV layout: when kv_heads don't divide the model axis (GQA on
    # wide TP), shard the cache SEQUENCE axis over `model` instead of
    # replicating the whole cache 16x per chip.
    "kv_seq_shard": False,
    # ZeRO-1: shard optimizer moments over the data axis.
    "zero1": False,
    # donate decode caches (in-place update instead of copy-on-write).
    "donate_caches": False,
    # remat policy that saves matmul outputs (avoids recomputing the TP
    # collectives feeding them in the backward pass).
    "remat_dots": False,
    # expert-parallel MoE via shard_map all-to-all (models/moe_ep.py).
    "moe_ep": False,
}


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
# name -> spec for the *unstacked* layer shape; block leaves get a leading
# None for the scan-stacked n_periods axis.
_COL = "model"      # output-dim sharded (column parallel)

_PARAM_RULES: Dict[str, Tuple] = {
    # top level
    "embedding": ("model", None),
    "head": (None, "model"),
    "ln_f": (None,),
    # attention
    "wq": (None, "model"),
    "wk": (None, "model"),
    "wv": (None, "model"),
    "wk_cross": (None, "model"),
    "wv_cross": (None, "model"),
    "wo": ("model", None),
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    # mlp
    "w_gate": (None, "model"),       # moe variant handled by ndim below
    "w_up": (None, "model"),
    "w_down": ("model", None),
    "router": (None, None),
    # mamba
    "w_z": (None, "model"),
    "w_x": (None, "model"),
    "w_B": (None, None),
    "w_C": (None, None),
    "w_dt": (None, "model"),
    "conv_x_w": (None, "model"),
    "conv_x_b": ("model",),
    "conv_B_w": (None, None),
    "conv_B_b": (None,),
    "conv_C_w": (None, None),
    "conv_C_b": (None,),
    "dt_bias": ("model",),
    "A_log": ("model",),
    "D": ("model",),
    "norm": ("model",),
    "w_out": ("model", None),
    # norms
    "ln1": (None,),
    "ln2": (None,),
}

# MoE expert tensors: (E, d, f) / (E, f, d) -> expert parallel over model
_MOE_RULES: Dict[str, Tuple] = {
    "w_gate": ("model", None, None),
    "w_up": ("model", None, None),
    "w_down": ("model", None, None),
}


def _leaf_name(path) -> str:
    for p in reversed(path):
        if hasattr(p, "key"):
            return str(p.key)
    return ""


def param_pspec(path, leaf, mesh: Mesh) -> P:
    name = _leaf_name(path)
    shape = tuple(leaf.shape)
    in_blocks = any(
        getattr(p, "key", None) == "blocks" for p in path
    )
    base_rank = len(shape) - (1 if in_blocks else 0)
    rules = _PARAM_RULES
    if name in _MOE_RULES and base_rank == 3:
        spec = _MOE_RULES[name]
    elif name in rules:
        spec = rules[name]
        if len(spec) != base_rank:
            spec = tuple(
                list(spec) + [None] * (base_rank - len(spec))
            )[:base_rank]
    else:
        spec = (None,) * base_rank
    if in_blocks:
        spec = (None,) + tuple(spec)
    return _guard(mesh, shape, spec)


def params_shardings(abstract_params: Any, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, mesh)),
        abstract_params,
    )


# ---------------------------------------------------------------------------
# Batches / activations
# ---------------------------------------------------------------------------
def data_pspec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Shard the leading (batch) axis over (pod, data) when divisible."""
    if not shape:
        return P()
    ba = batch_axes(mesh)
    spec = [ba if ba else None] + [None] * (len(shape) - 1)
    return _guard(mesh, shape, tuple(spec))


def batch_shardings(batch_tree: Any, mesh: Mesh):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, data_pspec(tuple(leaf.shape), mesh)),
        batch_tree,
    )


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------
def cache_pspec(path, leaf, mesh: Mesh) -> P:
    """Caches carry a leading n_periods axis.

    KV tensors (np, B, T, G, D): batch over (pod,data) if divisible, else
    sequence T over data (the long-context fallback); KV heads over model
    when divisible.
    SSM states   (np, B, h, p, n) / conv (np, B, w, ch): batch over
    (pod,data), heads/channels over model.
    """
    name = _leaf_name(path)
    shape = tuple(leaf.shape)
    ba = batch_axes(mesh)
    # leaves may or may not carry the leading n_periods axis (full stack vs
    # standalone super-block body)
    if name in ("k", "v") and len(shape) in (4, 5):
        stacked = len(shape) == 5
        B = shape[1] if stacked else shape[0]
        G = shape[3] if stacked else shape[2]
        lead = (None,) if stacked else ()
        heads_shardable = G % _axis_size(mesh, "model") == 0
        if OPT["kv_seq_shard"] and not heads_shardable:
            # GQA KV heads can't split the model axis: put the sequence
            # there instead of replicating the cache across it.
            if ba and B % _axis_size(mesh, ba) == 0:
                return _guard(mesh, shape, lead + (ba, "model", None, None))
            return _guard(
                mesh, shape, lead + (None, ("data", "model"), None, None)
            )
        if ba and B % _axis_size(mesh, ba) == 0:
            return _guard(mesh, shape, lead + (ba, None, "model", None))
        return _guard(mesh, shape, lead + (None, "data", "model", None))
    if name == "ssm" and len(shape) in (4, 5):
        lead = (None,) if len(shape) == 5 else ()
        return _guard(mesh, shape, lead + (ba, "model", None, None))
    if name.startswith("conv") and len(shape) in (3, 4):
        lead = (None,) if len(shape) == 4 else ()
        return _guard(mesh, shape, lead + (ba, None, "model"))
    # fallback: batch on axis 1 (stacked) / axis 0
    spec = [None] * len(shape)
    if len(shape) >= 2:
        spec[1] = ba
    return _guard(mesh, shape, tuple(spec))


def cache_shardings(cache_tree: Any, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_pspec(path, leaf, mesh)),
        cache_tree,
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def zero1_shardings(tree: Any, mesh: Mesh):
    """ZeRO-1 optimizer-moment layout: the parameter's own spec plus a
    ``data``-axis split on the first still-replicated divisible dimension
    (moments are only touched at the update, so the extra gather cost is
    one reduce-scatter/all-gather pair per step while memory drops ~16x)."""

    def spec(path, leaf):
        base = param_pspec(path, leaf, mesh)
        entries = list(base) + [None] * (len(leaf.shape) - len(base))
        dsz = _axis_size(mesh, "data")
        for i, (dim, e) in enumerate(zip(leaf.shape, entries)):
            if e is None and dim % dsz == 0 and dim >= dsz:
                entries[i] = "data"
                break
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(spec, tree)
