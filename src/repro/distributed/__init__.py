"""Distribution layer: sharding rules + multipath collective programs."""
from .mma_collectives import (
    make_kv_fetch_step,
    make_wakeup_step,
    staging_shardings,
)
from .sharding import (
    batch_shardings,
    cache_shardings,
    data_pspec,
    param_pspec,
    params_shardings,
    replicated,
)
