"""MMA-JAX: Multipath Memory Access for LLM serving, reproduced as a
multi-pod JAX/TPU framework.

Paper: "Multipath Memory Access: Breaking Host-GPU Bandwidth Bottlenecks
in LLM Serving" (CS.DC 2025). See DESIGN.md / EXPERIMENTS.md.

Subpackages:
    core         the paper's contribution (transfer engine, scheduler)
    models       composable transformer stack (dense/MoE/SSM/hybrid/VLM)
    kernels      Pallas TPU kernels with jnp oracles
    serving      KV/prefix cache, weight manager, scheduler, orchestrator
    training     optimizer, loop, data, checkpointing
    distributed  sharding rules + multipath collective programs
    configs      the 10 assigned architectures
    launch       meshes, dry-run, train/serve entry points
"""

__version__ = "1.0.0"
