"""KV-cache fetch serving scenario (paper §5.2.1): prefix-cache hits fetch
offloaded KV from host DRAM before decoding; MMA cuts the fetch time.

Shows (1) the paper-scale TTFT table on the simulated 8xH20 and (2) an
end-to-end functional server on CPU: requests arrive, get scheduled,
decode, finish, and their KV is offloaded; repeated prompts hit the
prefix cache.

Run:  PYTHONPATH=src python examples/kv_fetch_serving.py
"""
import numpy as np

from repro.configs import PAPER_MODELS, get_config
from repro.serving import FunctionalServer, LatencyModel


def paper_scale() -> None:
    print("== Paper-scale TTFT under prefix-cache hits ==")
    cfg = PAPER_MODELS["qwen-7b-chat"]
    for ctx in (16_384, 32_768, 65_536):
        tb = LatencyModel(cfg, use_mma=False).ttft(ctx)
        tm = LatencyModel(cfg, use_mma=True).ttft(ctx)
        print(f"ctx {ctx // 1024:3d}k: baseline {tb.ttft_s * 1e3:6.1f} ms "
              f"(fetch {tb.fetch_fraction:4.0%}) | "
              f"MMA {tm.ttft_s * 1e3:6.1f} ms | "
              f"{tb.ttft_s / tm.ttft_s:.2f}x")


def functional_serving() -> None:
    print("\n== Functional serving with KV offload + prefix cache ==")
    cfg = get_config("tinyllama-1.1b").reduced()
    srv = FunctionalServer(cfg, max_running=2,
                           device_budget_tokens=2048, max_len=128)
    rng = np.random.default_rng(0)
    prompt_a = rng.integers(0, cfg.vocab, size=64)
    prompt_b = rng.integers(0, cfg.vocab, size=48)
    for p in (prompt_a, prompt_b, prompt_a):  # third reuses A's prefix
        srv.submit(p, max_new_tokens=4)
    done = srv.run_until_done()
    for req in done:
        print(f"req {req.req_id}: {len(req.tokens)} prompt tokens, "
              f"generated {req.generated}, prefix hit {req.hit_tokens} "
              f"tokens, TTFT {req.ttft * 1e3:.0f} ms (CPU wall)")
    print(f"transfer log (kind, tokens): {srv.transfer_log}")
    tiers = srv.kv.tier_report()
    print(f"host store: {tiers['pages']} pages, tier bytes "
          f"{tiers['tier_bytes']}")


if __name__ == "__main__":
    paper_scale()
    functional_serving()
