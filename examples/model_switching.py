"""Model switching under vLLM-style Sleep Mode with MMA (paper §5.2.2).

Two model instances share one GPU's memory: switching puts one to sleep
(D2H through the multipath engine) and wakes the other (H2D). Shows both
the simulated paper-scale latencies (Qwen3-32B) and a real functional
round-trip with a reduced model whose weights survive bit-exactly.

Run:  PYTHONPATH=src python examples/model_switching.py
"""
import jax
import numpy as np

from repro.configs import PAPER_MODELS, get_config
from repro.core import make_functional_engine, make_sim_engine
from repro.core.config import MMAConfig
from repro.models import init_params
from repro.serving import LatencyModel, WeightManager


def paper_scale() -> None:
    print("== Paper-scale switching latency (simulated 8xH20) ==")
    for name in ("qwen3-4b", "qwen3-32b"):
        cfg = PAPER_MODELS[name]
        sb, wb = LatencyModel(cfg, use_mma=False).model_switch()
        sm, wm = LatencyModel(cfg, use_mma=True).model_switch()
        print(f"{name:10s}: sleep {sb:.2f}s -> {sm:.2f}s ({sb / sm:.2f}x)  "
              f"wake {wb:.2f}s -> {wm:.2f}s ({wb / wm:.2f}x)")


def functional_roundtrip() -> None:
    print("\n== Functional sleep/wake round-trip (reduced model) ==")
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    before = jax.tree.map(np.asarray, params)
    eng = make_functional_engine(
        config=MMAConfig(chunk_bytes=1 << 18, fallback_bytes=0)
    )
    wm = WeightManager(eng, params=params)
    print(f"weights: {wm.nbytes / (1 << 20):.1f} MB")
    r1 = wm.sleep()
    print(f"fall-asleep (D2H): {r1.seconds * 1e3:.1f} ms")
    assert wm.params is None  # GPU memory released
    r2 = wm.wake()
    print(f"wake-up (H2D multipath): {r2.seconds * 1e3:.1f} ms")
    same = all(
        np.array_equal(a, np.asarray(b))
        for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(wm.params))
    )
    print(f"weights bit-exact after round-trip: {same}")


if __name__ == "__main__":
    paper_scale()
    functional_roundtrip()
