"""End-to-end training driver: train a ~100M-param llama-family model for
a few hundred steps on CPU with the full substrate (synthetic data
pipeline with prefetch, AdamW + cosine schedule, checkpointing through the
MMA engine, loss curve).

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.training import (
    AdamWConfig,
    DataConfig,
    PrefetchLoader,
    SyntheticTokenStream,
    TrainConfig,
    train,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    # full-size variant of the brief's "~100M params, few hundred steps":
    #   --hundred-m --steps 300   (several CPU-hours; same code path)
    ap.add_argument("--hundred-m", action="store_true")
    args = ap.parse_args()

    # tinyllama family scaled down but real depth (~37M); --hundred-m
    # gives the brief's ~100M variant (slower on CPU).
    if args.hundred_m:
        cfg = dataclasses.replace(
            get_config("tinyllama-1.1b"),
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=2304, vocab=16384, dtype=jnp.float32,
        )
    else:
        cfg = dataclasses.replace(
            get_config("tinyllama-1.1b"),
            n_layers=8, d_model=512, n_heads=8, n_kv_heads=2, head_dim=64,
            d_ff=1536, vocab=8192, dtype=jnp.float32,
        )
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n / 1e6:.1f}M params, {cfg.n_layers}L d{cfg.d_model}")

    stream = SyntheticTokenStream(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch)
    )
    loader = PrefetchLoader(stream, depth=2)
    tc = TrainConfig(
        steps=args.steps,
        log_every=max(args.steps // 10, 1),
        checkpoint_every=max(args.steps // 2, 1),
        checkpoint_path="/tmp/repro_train_small.npz",
        remat=False,
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    )
    _, _, history = train(
        cfg, params, loader, tc,
        on_step=lambda s, m: print(
            f"step {m['step']:4d}  loss {m['loss']:.4f}  "
            f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}  "
            f"{m['wall_s']:.0f}s"
        ),
    )
    loader.close()
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
