"""Quickstart: the MMA engine in three views.

1. Simulated 8xH20: peak multipath bandwidth vs native (the paper's Fig 7
   headline).
2. Functional data plane: a real host array moved over direct + relay
   paths, bit-exact.
3. CUDA-stream semantics: an async copy behind a Dummy Task releasing
   downstream work exactly on completion.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    Direction,
    MMAConfig,
    SimStream,
    make_functional_engine,
    make_sim_engine,
    multipath_device_put,
)
from repro.core.config import GB, MB


def sim_bandwidth() -> None:
    print("== 1. Simulated 8xH20 bandwidth ==")
    eng, world, backend = make_sim_engine()
    task = eng.memcpy(1 * GB, device=0, direction=Direction.H2D)
    world.run()
    print(f"MMA H2D 1GB: {task.bandwidth_gbps():.1f} GB/s "
          f"(native single PCIe: ~53.6) — "
          f"{task.bandwidth_gbps() / 53.6:.2f}x")
    stats = {d: (w.chunks_direct, w.chunks_relay)
             for d, w in eng.workers.items()}
    print(f"chunks per link (direct, relay): {stats}")


def functional_dataplane() -> None:
    print("\n== 2. Functional multipath data plane ==")
    eng = make_functional_engine(
        config=MMAConfig(chunk_bytes=1 * MB, fallback_bytes=0)
    )
    x = np.random.default_rng(0).standard_normal((1024, 1024)).astype("f4")
    y = multipath_device_put(x, target=0, engine=eng)
    print(f"moved {x.nbytes / MB:.0f} MB in "
          f"{eng.config.n_chunks(x.nbytes)} chunks -> device {y.device}; "
          f"bit-exact: {np.array_equal(np.asarray(y), x)}")


def stream_semantics() -> None:
    print("\n== 3. Dummy-Task stream semantics (C2) ==")
    eng, world, _ = make_sim_engine()
    stream = SimStream(world, "user-stream")
    dummy = eng.memcpy_async(256 * MB, device=0, direction=Direction.H2D)
    stream.compute(2e-3, label="upstream-kernel")
    stream.dummy(dummy, label="intercepted-copy")
    stream.compute(1e-3, label="downstream-kernel")
    world.run()
    for label, t in stream.history:
        print(f"  {t * 1e3:7.2f} ms  {label}")
    print("downstream released exactly at multipath completion: "
          f"{stream.completion_time('intercepted-copy'):.6f}s == "
          f"{dummy.task.complete_time:.6f}s")


if __name__ == "__main__":
    sim_bandwidth()
    functional_dataplane()
    stream_semantics()
